"""Load-aware routing: least-outstanding dispatch under skewed load,
straggler-flag avoidance fed by RecoveryEngine per-instance step
latencies (PlannerStats.rank_step_times), and deterministic tie-breaks."""
import numpy as np

from repro.serve import (LoadAwareRouter, ReplicaPool, ReplicaView,
                         ServeConfig)


def _view(rid, outstanding=0, straggler=False):
    return ReplicaView(replica_id=rid, free_slots=1,
                       outstanding=outstanding, step_ewma=0.0,
                       straggler=straggler)


# ----------------------------------------------------------------------
# router units
# ----------------------------------------------------------------------
def test_load_aware_picks_least_outstanding():
    r = LoadAwareRouter()
    assert r.choose([], [_view(0, 3), _view(1, 1), _view(2, 2)]) == 1


def test_load_aware_tie_breaks_to_lower_id():
    r = LoadAwareRouter()
    assert r.choose([], [_view(1, 2), _view(0, 2)]) == 0


def test_load_aware_avoids_flagged_straggler():
    r = LoadAwareRouter()
    # replica 0 is less loaded but currently flagged slow
    views = [_view(0, 0, straggler=True), _view(1, 2)]
    assert r.choose([], views) == 1
    # with every candidate flagged, load decides again
    views = [_view(0, 2, straggler=True), _view(1, 1, straggler=True)]
    assert r.choose([], views) == 1


# ----------------------------------------------------------------------
# cluster: skewed queues
# ----------------------------------------------------------------------
def test_cluster_load_aware_routes_around_busy_replica(serve_model):
    bundle, params = serve_model
    V = bundle.cfg.vocab
    rng = np.random.default_rng(0)
    scfg = ServeConfig(max_seq=64, slots=2)
    pool = ReplicaPool(bundle, params, scfg, replicas=2, instances=2,
                       policy="load_aware")
    # two long-running requests: the load-aware tie-breaks place one
    # per replica (0 then 1)
    long_a = pool.submit(rng.integers(0, V, 6), max_new=12)
    long_b = pool.submit(rng.integers(0, V, 6), max_new=12)
    # one short request -> replica 0 (tied outstanding, lower id)
    short = pool.submit(rng.integers(0, V, 4), max_new=2)
    for _ in range(3):
        pool.step()
    recs = pool.metrics.requests
    assert recs[long_a].replica == 0
    assert recs[long_b].replica == 1
    assert recs[short].replica == 0
    assert pool.status(short) == "done"
    # replica 1 still has a live slot + a fresh free slot; replica 0
    # now has one live slot and one free -> tie broken by outstanding:
    # both have 1 outstanding, so the lower id (0) wins again
    tie = pool.submit(rng.integers(0, V, 4), max_new=2)
    pool.step()
    assert recs[tie].replica == 0
    # skew replica 0: fill BOTH its slots with long work, then the
    # next request must land on replica 1 despite the id tie-break
    filler = pool.submit(rng.integers(0, V, 4), max_new=12)
    pool.step()
    assert recs[filler].replica == 0
    skewed = pool.submit(rng.integers(0, V, 4), max_new=2)
    pool.step()
    assert recs[skewed].replica == 1
    pool.run(max_ticks=40)


# ----------------------------------------------------------------------
# straggler signal: per-instance latency -> monitor -> router
# ----------------------------------------------------------------------
def test_recovery_engine_surfaces_rank_step_times(serve_model):
    """Satellite: RecoveryEngine.step() lands per-instance latency in
    PlannerStats.rank_step_times (dead instances report 0.0)."""
    from repro.serve import RecoveryEngine

    bundle, params = serve_model
    V = bundle.cfg.vocab
    rng = np.random.default_rng(1)
    eng = RecoveryEngine(bundle, params, ServeConfig(max_seq=64, slots=2),
                         instances=3)
    eng.step_cost = {1: 0.25}
    eng.add_request(rng.integers(0, V, 5))
    eng.step()
    times = eng.rt.planner.stats.rank_step_times
    assert len(times) == 1
    step, ts = times[0]
    assert len(ts) == 3 and all(t > 0 for t in ts)
    # the injected slowdown is attributed to instance 1 only
    assert ts[1] >= ts[0] + 0.25 and ts[1] >= ts[2] + 0.25
    assert eng.last_step_time == max(ts)
    # a dead instance reports 0.0
    eng.fail_instance(1)
    eng.step()
    _, ts2 = eng.rt.planner.stats.rank_step_times[-1]
    assert ts2[1] == 0.0 and ts2[0] > 0 and ts2[2] > 0


def test_cluster_straggler_flag_steers_load_aware_router(serve_model):
    bundle, params = serve_model
    V = bundle.cfg.vocab
    rng = np.random.default_rng(2)
    scfg = ServeConfig(max_seq=64, slots=2)
    pool = ReplicaPool(bundle, params, scfg, replicas=2, instances=2,
                       policy="load_aware", straggler_threshold=2.0,
                       straggler_cooldown=16)
    # make replica 0's instance 0 slow: its injected step cost rides
    # into the pool's per-replica step times
    pool.replicas[0].step_cost = {0: 0.5}
    # keep BOTH replicas decoding so the monitor sees comparable work
    a = pool.submit(rng.integers(0, V, 5), max_new=10)
    b = pool.submit(rng.integers(0, V, 5), max_new=10)
    for _ in range(6):                 # monitor warmup is 3 ticks
        pool.step()
    recs = pool.metrics.requests
    assert recs[a].replica == 0 and recs[b].replica == 1
    assert any(e["kind"] == "straggler" and e["replica"] == 0
               for e in pool.metrics.events)
    # replica 0 has the FREE slot advantage-by-id, but the flag steers
    # the new request to healthy replica 1
    c = pool.submit(rng.integers(0, V, 4), max_new=2)
    pool.step()
    assert recs[c].replica == 1
    pool.run(max_ticks=40)


def test_round_robin_spreads_evenly(serve_model):
    bundle, params = serve_model
    V = bundle.cfg.vocab
    rng = np.random.default_rng(3)
    scfg = ServeConfig(max_seq=64, slots=2)
    pool = ReplicaPool(bundle, params, scfg, replicas=2, instances=2,
                       policy="round_robin")
    rids = [pool.submit(rng.integers(0, V, 4), max_new=2)
            for _ in range(4)]
    pool.run(max_ticks=40)
    assignment = [pool.metrics.requests[r].replica for r in rids]
    assert assignment == [0, 1, 0, 1]
