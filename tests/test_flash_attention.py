"""Flash-attention: blockwise / banded / Pallas(interpret) vs dense oracle.

Sweeps shapes, dtypes, GQA ratios, windows, softcaps, ragged offsets —
the per-kernel allclose requirement of deliverable (c)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import dense_attention, flash_attention
from repro.kernels.flash_attention.jnp_impl import (banded_attention,
                                                    blockwise_attention)
from repro.kernels.flash_attention.kernel import flash_attention_pallas


def _mk(B, T, S, Hq, Hkv, Dh, Dv, dtype, seed=0, ragged=False):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, T, Hq, Dh)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, Dh)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, Dv)), dtype)
    if ragged:
        off = rng.integers(0, S - T + 1, (B,))
    else:
        off = np.zeros((B,), np.int64)
    qpos = jnp.asarray(off[:, None] + np.arange(T)[None, :], jnp.int32)
    return q, k, v, qpos


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


CASES = [
    # B, T, S, Hq, Hkv, Dh, Dv, window, softcap, dtype, ragged
    (2, 64, 64, 4, 4, 32, 32, None, 0.0, jnp.float32, False),
    (1, 128, 128, 8, 2, 16, 16, None, 0.0, jnp.float32, False),
    (2, 96, 96, 4, 1, 32, 32, 24, 0.0, jnp.float32, False),     # MQA + window
    (1, 64, 64, 4, 4, 32, 32, None, 30.0, jnp.float32, False),  # softcap
    (2, 33, 77, 4, 2, 16, 48, None, 0.0, jnp.float32, True),    # ragged, Dv!=Dh, unaligned
    (2, 64, 64, 4, 4, 32, 32, None, 0.0, jnp.bfloat16, False),
    (1, 80, 160, 8, 8, 64, 64, 40, 0.0, jnp.bfloat16, True),
]


@pytest.mark.parametrize("case", CASES, ids=[str(i) for i in range(len(CASES))])
def test_blockwise_matches_dense(case):
    B, T, S, Hq, Hkv, Dh, Dv, w, cap, dt, ragged = case
    q, k, v, qpos = _mk(B, T, S, Hq, Hkv, Dh, Dv, dt, ragged=ragged)
    want = dense_attention(q, k, v, qpos=qpos, window=w, softcap=cap)
    got = blockwise_attention(q, k, v, qpos=qpos, window=w, softcap=cap,
                              block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("case", CASES, ids=[str(i) for i in range(len(CASES))])
def test_pallas_matches_dense(case):
    B, T, S, Hq, Hkv, Dh, Dv, w, cap, dt, ragged = case
    q, k, v, qpos = _mk(B, T, S, Hq, Hkv, Dh, Dv, dt, ragged=ragged)
    want = dense_attention(q, k, v, qpos=qpos, window=w, softcap=cap)
    got = flash_attention_pallas(q, k, v, qpos=qpos, window=w, softcap=cap,
                                 block_q=32, block_kv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("window", [8, 24, 64])
def test_banded_matches_dense(window):
    q, k, v, qpos = _mk(2, 96, 96, 4, 2, 32, 32, jnp.float32, seed=3)
    want = dense_attention(q, k, v, qpos=qpos, window=window)
    got = banded_attention(q, k, v, qpos=qpos, window=window, block_q=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_banded_ragged_offsets():
    q, k, v, qpos = _mk(3, 16, 128, 4, 4, 16, 16, jnp.float32, seed=5,
                        ragged=True)
    want = dense_attention(q, k, v, qpos=qpos, window=32)
    got = banded_attention(q, k, v, qpos=qpos, window=32, block_q=8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_traced_window_blockwise():
    """gemma2 path: window is a traced scalar inside scan."""
    q, k, v, qpos = _mk(1, 64, 64, 4, 4, 16, 16, jnp.float32)

    def f(w):
        return blockwise_attention(q, k, v, qpos=qpos, window=w,
                                   block_q=16, block_kv=16)
    got = jax.jit(f)(jnp.asarray(24))
    want = dense_attention(q, k, v, qpos=qpos, window=24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_auto_dispatch():
    q, k, v, qpos = _mk(1, 32, 32, 2, 2, 16, 16, jnp.float32)
    a = flash_attention(q, k, v, qpos=qpos)
    b = dense_attention(q, k, v, qpos=qpos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-5)


def test_fully_masked_rows_zero():
    """Queries with qpos == -1 (padding) must produce exact zeros."""
    q, k, v, _ = _mk(1, 8, 16, 2, 2, 8, 8, jnp.float32)
    qpos = jnp.full((1, 8), -1, jnp.int32)
    for fn in (dense_attention,
               lambda *a, **kw: blockwise_attention(*a, block_q=4,
                                                    block_kv=8, **kw),
               lambda *a, **kw: flash_attention_pallas(*a, block_q=4,
                                                       block_kv=8,
                                                       interpret=True, **kw)):
        out = fn(q, k, v, qpos=qpos)
        np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("case_i", [0, 2, 3, 4])
def test_blockwise_custom_vjp_grads(case_i):
    """Flash backward (custom VJP) vs autodiff through the dense oracle."""
    B, T, S, Hq, Hkv, Dh, Dv, w, cap, dt, ragged = CASES[case_i]
    q, k, v, qpos = _mk(B, T, S, Hq, Hkv, Dh, Dv, jnp.float32, ragged=ragged)

    def loss_dense(q, k, v):
        return jnp.sum(jnp.square(dense_attention(
            q, k, v, qpos=qpos, window=w, softcap=cap)))

    def loss_block(q, k, v):
        return jnp.sum(jnp.square(blockwise_attention(
            q, k, v, qpos=qpos, window=w, softcap=cap,
            block_q=32, block_kv=32)))

    gd = jax.grad(loss_dense, argnums=(0, 1, 2))(q, k, v)
    gb = jax.grad(loss_block, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gd, gb):
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-4, atol=2e-4)
