"""Deliverable (e) in CI form: one real dry-run cell compiles for the
production 256-chip mesh in a subprocess (the 512 placeholder devices
require a fresh process — jax pins the device count at first init)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.timeout(600)
def test_dryrun_cell_compiles_on_production_mesh(tmp_path):
    # REPRO_RESULTS_DIR keeps the run out of the committed baselines in
    # results/dryrun — a regeneration on this host is not a measurement.
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_RESULTS_DIR=str(tmp_path))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "whisper-base", "--shape", "decode_32k",
         "--mesh", "single", "--force"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    path = os.path.join(str(tmp_path),
                        "whisper-base__decode_32k__pod16x16.json")
    with open(path) as f:
        rec = json.load(f)
    assert rec["status"] == "ok"
    rl = rec["roofline"]
    assert rl["n_chips"] == 256
    assert rl["hlo_flops"] > 0 and rl["hlo_bytes"] > 0
    assert rl["bottleneck"] in ("compute", "memory", "collective")
    # the serve rules must have been selected for a decode cell
    assert rec["rules"] == "serve"
    # memory_analysis printed per-device stats
    assert rec["memory"]["total_hbm_bytes"] > 0
