"""MLA: the naive (train/prefill, T>=1024) and absorbed (decode/dense)
forms must agree — they are algebraically identical attention."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, MLACfg
from repro.models import mla as MLA


def _cfg():
    return ArchConfig(
        name="mla-test", family="moe", n_layers=1, d_model=32, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab=128, d_head=8,
        mla=MLACfg(q_lora=16, kv_lora=16, d_nope=8, d_rope=4, d_v=8))


def test_naive_flash_matches_absorbed_dense():
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p, _ = MLA.mla_params(key, cfg, n_layers=1)
    pl = jax.tree.map(lambda a: a[0], p)
    B, T = 2, 1024        # T >= 1024 -> naive flash path
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model),
                          jnp.float32)
    out_naive, _ = MLA.mla_attention(pl, x, cfg)

    # absorbed dense oracle: chunk x so T < 1024 never triggers flash,
    # but causality couples chunks — instead run the absorbed path by
    # monkeypatching the threshold via a cache of exactly T (dense
    # branch handles cache path for any T below the flash threshold).
    # Simplest exact check: recompute with the absorbed equations here.
    from repro.models.common import make_causal_mask, rms_norm, rope
    import math
    m, H = cfg.mla, cfg.n_heads
    cdt = x.dtype
    q = rms_norm(x @ pl["wq_a"], pl["q_norm"]) @ pl["wq_b"]
    q_nope, q_rope = MLA._split_q(q, H, m)
    kv = x @ pl["wkv_a"]
    c_kv, k_rope = kv[..., :m.kv_lora], kv[..., m.kv_lora:]
    c_kv = rms_norm(c_kv, pl["kv_norm"])
    positions = jnp.arange(T)[None, :]
    q_rope = rope(q_rope, positions, cfg.rope_base)
    k_rope_r = rope(k_rope[..., None, :], positions, cfg.rope_base)[..., 0, :]
    wk_b = pl["wk_b"].reshape(m.kv_lora, H, m.d_nope)
    q_abs = jnp.einsum("bthd,chd->bthc", q_nope, wk_b)
    scale = 1.0 / math.sqrt(m.d_nope + m.d_rope)
    s = (jnp.einsum("bthc,bsc->bhts", q_abs, c_kv)
         + jnp.einsum("bthr,bsr->bhts", q_rope, k_rope_r)) * scale
    mask = make_causal_mask(T, T, 0)
    s = jnp.where(mask[None, None], s.astype(jnp.float32), -1e30)
    probs = jax.nn.softmax(s, -1).astype(cdt)
    o_lat = jnp.einsum("bhts,bsc->bthc", probs, c_kv)
    wv_b = pl["wv_b"].reshape(m.kv_lora, H, m.d_v)
    o = jnp.einsum("bthc,chv->bthv", o_lat, wv_b)
    want = o.reshape(B, T, H * m.d_v) @ pl["wo"]

    np.testing.assert_allclose(np.asarray(out_naive), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_decode_consistent_with_prefill():
    """Absorbed decode continues exactly where dense prefill stopped."""
    cfg = _cfg()
    key = jax.random.PRNGKey(0)
    p, _ = MLA.mla_params(key, cfg, n_layers=1)
    pl = jax.tree.map(lambda a: a[0], p)
    B, T = 1, 12
    x = jax.random.normal(jax.random.PRNGKey(2), (B, T + 1, cfg.d_model),
                          jnp.float32)
    # full forward over T+1 tokens
    full, _ = MLA.mla_attention(pl, x, cfg)
    # prefill T then decode 1
    cache = {"ckv": jnp.zeros((B, 64, cfg.mla.kv_lora + cfg.mla.d_rope),
                              jnp.float32),
             "pos": jnp.zeros((B,), jnp.int32)}
    _, cache = MLA.mla_attention(pl, x[:, :T], cfg, cache=cache)
    dec, _ = MLA.mla_attention(pl, x[:, T:], cfg, cache=cache)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, T]), rtol=2e-4, atol=2e-4)
