"""Unit tests for the fault-tolerance building blocks.

StragglerMonitor EWMA behavior (threshold crossings, alpha edge
cases), the deterministic FaultInjector (sites, kinds, repetition),
StepGuard retry/backoff, the shrink/inherit/survivor partition
algebra, and the CheckpointManager runtime save/restore gates —
everything below the run_pipeline recovery loop, which
tests/test_fault_recovery.py exercises end to end.
"""
from __future__ import annotations

import tempfile

import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.core import HDArrayRuntime
from repro.core.sections import Box, SectionSet
from repro.ft.faults import (FaultInjector, FaultSpec, RankLostFault,
                             StepGuard, StragglerMonitor, TransientFault,
                             coverage_box, inherit_partition,
                             shrink_partition, survivor_partition)


# ---------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------
def test_straggler_threshold_crossing():
    m = StragglerMonitor(threshold=2.0, alpha=0.1, warmup=3)
    for i in range(6):
        assert not m.observe(i, 1.0)
    assert m.observe(6, 2.5)          # 2.5 > 2.0 * 1.0
    assert len(m.events) == 1
    assert m.events[0].step == 6 and m.events[0].duration == 2.5
    # the straggler did not poison the average
    assert abs(m.ewma - 1.0) < 1e-9
    assert not m.observe(7, 1.1)


def test_straggler_warmup_suppresses_early_flags():
    m = StragglerMonitor(threshold=2.0, warmup=5)
    assert not m.observe(0, 1.0)      # seeds the EWMA
    for i in range(1, 5):             # _n <= warmup: never flagged
        assert not m.observe(i, 100.0)
    # warmup passed AND the huge early samples inflated the average,
    # so a merely-slow step is no longer an outlier
    assert m.ewma > 1.0


def test_straggler_alpha_zero_freezes_ewma():
    # alpha=0: the average never moves off the first sample
    m = StragglerMonitor(threshold=2.0, alpha=0.0, warmup=0)
    m.observe(0, 1.0)
    for i in range(1, 4):
        m.observe(i, 1.9)             # below threshold, would drift
    assert m.ewma == 1.0
    assert m.observe(4, 2.1)


def test_straggler_alpha_one_tracks_last_sample():
    # alpha=1: the average IS the last non-straggler duration
    m = StragglerMonitor(threshold=2.0, alpha=1.0, warmup=0)
    m.observe(0, 1.0)
    m.observe(1, 5.0)                 # 5 > 2*1: straggler, ewma stays 1
    assert m.ewma == 1.0
    m.observe(2, 1.5)                 # 1.5 <= 2: ewma jumps to 1.5
    assert m.ewma == 1.5


# ---------------------------------------------------------------------
# FaultInjector
# ---------------------------------------------------------------------
def test_injector_bare_ints_fire_once():
    inj = FaultInjector([2, 5])
    inj.maybe_fail(0)
    with pytest.raises(TransientFault):
        inj.maybe_fail(2)
    inj.maybe_fail(2)                 # fired already: silent on replay
    with pytest.raises(TransientFault):
        inj.maybe_fail(5)
    assert inj.fired == {2, 5}
    assert inj.fail_at == {2, 5}
    assert inj.log == [(2, "step", "transient"), (5, "step", "transient")]


def test_injector_is_deterministic():
    def drive(inj):
        log = []
        for i in range(6):
            for site in ("step", "commit"):
                try:
                    inj.maybe_fail(i, site=site)
                except (TransientFault, RankLostFault):
                    pass
        return list(inj.log)

    specs = [FaultSpec(1), FaultSpec(3, site="commit"),
             FaultSpec(4, kind="rank", rank=2)]
    assert drive(FaultInjector(specs)) == drive(FaultInjector(specs))


def test_injector_site_filtering():
    inj = FaultInjector([FaultSpec(3, site="commit")])
    inj.maybe_fail(3, site="step")    # wrong site: no fire
    with pytest.raises(TransientFault):
        inj.maybe_fail(3, site="commit")


def test_injector_times_and_rank_kind():
    inj = FaultInjector([FaultSpec(1, times=2),
                         FaultSpec(2, kind="rank", rank=3)])
    for _ in range(2):
        with pytest.raises(TransientFault):
            inj.maybe_fail(1)
    inj.maybe_fail(1)                 # times exhausted
    with pytest.raises(RankLostFault) as ei:
        inj.maybe_fail(2)
    assert ei.value.rank == 3
    # RankLostFault is deliberately NOT a TransientFault: retry cannot
    # resurrect a dead rank, so StepGuard must not swallow it
    assert not isinstance(ei.value, TransientFault)


# ---------------------------------------------------------------------
# StepGuard
# ---------------------------------------------------------------------
def test_stepguard_exponential_backoff_and_reset():
    sleeps = []
    restores = []

    def restore_fn():
        restores.append(True)
        return 0, "state"

    guard = StepGuard(restore_fn, max_retries=5, backoff=0.1,
                      sleep=sleeps.append)
    fail = [True, True, True, False]

    def step():
        if fail.pop(0):
            raise TransientFault("boom")
        return "ok"

    for _ in range(3):
        out, replay = guard.run(7, step)
        assert out is None and replay == (0, "state")
    out, replay = guard.run(7, step)
    assert out == "ok" and replay is None
    assert sleeps == pytest.approx([0.1, 0.2, 0.4])
    assert guard.retries == 0          # success resets the streak
    assert guard.recoveries == [7, 7, 7]
    assert len(restores) == 3


def test_stepguard_exhausts_retries():
    guard = StepGuard(lambda: (0, None), max_retries=2, sleep=lambda _s: None)

    def always_fail():
        raise TransientFault("boom")

    for _ in range(2):
        guard.run(0, always_fail)
    with pytest.raises(TransientFault):
        guard.run(0, always_fail)


def test_stepguard_does_not_catch_rank_loss():
    guard = StepGuard(lambda: (0, None))

    def lose_rank():
        raise RankLostFault(1)

    with pytest.raises(RankLostFault):
        guard.run(0, lose_rank)


# ---------------------------------------------------------------------
# partition algebra of a mesh shrink
# ---------------------------------------------------------------------
def test_shrink_partition_redistributes_evenly():
    rt = HDArrayRuntime(4, backend="null")
    pid = rt.partition_row((16, 8))
    new = shrink_partition(rt, pid, live=[0, 1, 3])
    part = rt.parts[new]
    assert part.regions[2].is_empty()
    assert [r.bounds[0] for r in part.regions if not r.is_empty()] \
        == [(0, 6), (6, 11), (11, 16)]
    # coverage is preserved exactly
    u = SectionSet.empty(2)
    for r in part.regions:
        if not r.is_empty():
            u = u.union(SectionSet.of(r))
    assert u == SectionSet.full((16, 8))


def test_shrink_partition_of_interior_work_region():
    rt = HDArrayRuntime(4, backend="null")
    pid = rt.partition_row((16, 16), region=Box.make((1, 15), (1, 15)))
    new = shrink_partition(rt, pid, live=[1, 2])
    part = rt.parts[new]
    assert part.regions[0].is_empty() and part.regions[3].is_empty()
    assert part.regions[1].bounds == ((1, 8), (1, 15))
    assert part.regions[2].bounds == ((8, 15), (1, 15))


def test_shrink_partition_rejects_non_box_coverage():
    rt = HDArrayRuntime(2, backend="null")
    # two regions whose union is L-shaped: no box tiles it
    pid = rt.partition_manual((8, 8), [Box.make((0, 4), (0, 8)),
                                       Box.make((4, 8), (0, 4))])
    with pytest.raises(ValueError, match="does not tile a box"):
        shrink_partition(rt, pid, live=[0])


def test_coverage_box_requires_regions():
    with pytest.raises(ValueError, match="no non-empty regions"):
        coverage_box([Box(((0, 0), (0, 0)))])


def test_inherit_partition_absorbs_dead_region():
    rt = HDArrayRuntime(4, backend="null")
    pid = rt.partition_row((16, 8))
    new = inherit_partition(rt, pid, live=[0, 1, 3])
    part = rt.parts[new]
    # rank 2's rows merge into a neighbor; survivors keep their own
    assert part.regions[2].is_empty()
    assert part.regions[0].bounds[0] == (0, 4)
    merged = {part.regions[1].bounds[0], part.regions[3].bounds[0]}
    assert merged == {(4, 12), (12, 16)} or merged == {(4, 8), (8, 16)}


def test_inherit_partition_returns_none_when_unmergeable():
    rt = HDArrayRuntime(2, backend="null")
    # the dead region is not box-mergeable with the sole survivor
    pid = rt.partition_manual((12, 12), [Box.make((0, 4), (0, 4)),
                                         Box.make((8, 12), (8, 12))])
    assert inherit_partition(rt, pid, live=[0]) is None


def test_survivor_partition_covers_domain():
    rt = HDArrayRuntime(5, backend="null")
    pid = survivor_partition(rt, (13, 7), live=[1, 4])
    part = rt.parts[pid]
    assert [p for p, r in enumerate(part.regions) if not r.is_empty()] \
        == [1, 4]
    assert part.regions[1].bounds == ((0, 7), (0, 7))
    assert part.regions[4].bounds == ((7, 13), (0, 7))


# ---------------------------------------------------------------------
# CheckpointManager runtime path
# ---------------------------------------------------------------------
def test_save_restore_runtime_roundtrip_sim():
    rng = np.random.default_rng(3)
    data = {"x": rng.standard_normal((8, 8)).astype(np.float32),
            "y": rng.standard_normal((8, 8)).astype(np.float32)}
    with tempfile.TemporaryDirectory() as d:
        rt = HDArrayRuntime(3)
        pd = rt.partition_row((8, 8))
        for name, v in data.items():
            rt.write(rt.create(name, (8, 8)), v, pd)
        cm = CheckpointManager(d)
        cm.save_runtime(7, rt)
        # clobber everything, then restore
        for name in data:
            rt.write(rt.arrays[name], np.zeros((8, 8), np.float32), pd)
        step = cm.restore_runtime(rt)
        assert step == 7
        for name, v in data.items():
            np.testing.assert_array_equal(rt.read_coherent(rt.arrays[name]),
                                          v)
        assert rt.planner.stats.checkpoint_restores == 2
        restores = [e for e in rt.comm_log if e[0].startswith("__restore_")]
        assert {e[0] for e in restores} == {"__restore_x", "__restore_y"}


def test_save_runtime_async_then_restore():
    with tempfile.TemporaryDirectory() as d:
        rt = HDArrayRuntime(2)
        arr = rt.create("a", (6, 6))
        pd = rt.partition_row((6, 6))
        v = np.arange(36, dtype=np.float32).reshape(6, 6)
        rt.write(arr, v, pd)
        cm = CheckpointManager(d)
        cm.save_runtime(1, rt, blocking=False)
        cm.wait()
        rt.write(arr, np.zeros((6, 6), np.float32), pd)
        assert cm.restore_runtime(rt) == 1
        np.testing.assert_array_equal(rt.read_coherent(arr), v)


def test_save_runtime_rejects_incoherent_array():
    with tempfile.TemporaryDirectory() as d:
        rt = HDArrayRuntime(2)
        rt.create("a", (4, 4))         # never written: no coherent cover
        with pytest.raises(ValueError, match="coherent cover"):
            CheckpointManager(d).save_runtime(0, rt)


def test_restore_runtime_busts_plan_cache():
    """A restore rewrites coherence state, so a plan cached before the
    fault must NOT be replayed verbatim after it."""
    from repro.core import AccessSpec
    ident = AccessSpec.of((0, 0))
    with tempfile.TemporaryDirectory() as d:
        rt = HDArrayRuntime(2)
        arr = rt.create("a", (8, 8))
        pd = rt.partition_row((8, 8))
        pc = rt.partition_col((8, 8))
        rt.write(arr, np.ones((8, 8), np.float32), pd)
        cm = CheckpointManager(d)
        cm.save_runtime(0, rt)
        # a repeated col-partition read plans once, then caches
        for _ in range(3):
            rt.plan_only("k", pc, [arr], {"a": ident}, {"a": ident})
        cached_before = rt.planner.stats.plans_cached
        assert cached_before > 0
        cm.restore_runtime(rt)
        plan = rt.plan_only("k", pc, [arr], {"a": ident}, {"a": ident})
        assert not plan.cached
        np.testing.assert_array_equal(rt.read_coherent(arr),
                                      np.ones((8, 8), np.float32))


def test_drop_rank_poisons_sim_buffer():
    rt = HDArrayRuntime(2)
    arr = rt.create("a", (4, 4))
    pd = rt.partition_row((4, 4))
    rt.write(arr, np.ones((4, 4), np.float32), pd)
    rt.executor.drop_rank(arr, 1)
    assert np.isnan(rt.executor.buffers["a"][1]).all()
    assert np.all(rt.executor.buffers["a"][0][0:2] == 1.0)


def test_mark_rank_lost_clears_coherence_state():
    rt = HDArrayRuntime(3)
    arr = rt.create("a", (9, 9))
    pd = rt.partition_row((9, 9))
    rt.write(arr, np.ones((9, 9), np.float32), pd)
    arr.mark_rank_lost(1)
    assert arr.valid[1].is_empty()
    assert not arr.coherent_cover()    # rows 3..6 lost until restore
    for q in range(3):
        if q != 1:
            assert arr.sgdef[q][1].is_empty()   # pending sends to dead
            assert not arr.valid[q].is_empty()  # survivors keep theirs
